//! The worker pool: construction, root-task submission, shutdown.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::deque::{Deque, SubmissionQueue};
use crate::frame::{FrameHeader, FrameKind, FramePtr, JoinCounter};
use crate::metrics::{Metrics, MetricsSnapshot};
use crate::numa::{AliasSampler, NumaTopology};
use crate::sched::SchedulerKind;
use crate::stack::SegmentedStack;
use crate::sync::{CachePadded, Parker};
use crate::task::{Coroutine, Frame};

/// Completion signal for a root task (non-generic part). The submitter
/// either parks on it (blocking `join`) or registers a [`Waker`]
/// (async `await`); the worker finishing the root notifies both.
#[derive(Debug)]
pub struct RootSignal {
    done: AtomicBool,
    parker: Parker,
    /// Waker registered by an async awaiter (at most one — `RootHandle`
    /// is not cloneable). Guarded by a mutex rather than an atomic state
    /// machine: registration/completion happen once per root, never on
    /// the fork/join hot path.
    waker: std::sync::Mutex<Option<std::task::Waker>>,
}

impl RootSignal {
    fn new() -> Self {
        RootSignal {
            done: AtomicBool::new(false),
            parker: Parker::new(),
            waker: std::sync::Mutex::new(None),
        }
    }

    /// Worker side: publish completion (Release) and wake the submitter —
    /// both the blocking parker and any registered async waker.
    pub fn complete(&self) {
        self.done.store(true, Ordering::Release);
        self.parker.notify();
        // Lock ordering vs `register_waker`: `done` is set before taking
        // the lock here, and `poll` re-checks `done` after releasing it,
        // so either we see the waker or the poller sees completion.
        let waker = self.waker.lock().unwrap().take();
        if let Some(w) = waker {
            w.wake();
        }
    }

    /// Async side: (re-)register the waker to be called on completion.
    /// The caller must re-check [`Self::is_done`] afterwards to close the
    /// race with a concurrent [`Self::complete`].
    pub fn register_waker(&self, waker: &std::task::Waker) {
        let mut slot = self.waker.lock().unwrap();
        // Skip the clone when re-registering the same waker.
        match &mut *slot {
            Some(w) if w.will_wake(waker) => {}
            other => *other = Some(waker.clone()),
        }
    }

    /// Submitter side: block until complete.
    pub fn wait(&self) {
        while !self.done.load(Ordering::Acquire) {
            self.parker.park_timeout(std::time::Duration::from_millis(50));
        }
    }

    /// Non-blocking completion check.
    pub fn is_done(&self) -> bool {
        self.done.load(Ordering::Acquire)
    }
}

/// State shared by all workers of a pool.
pub struct Shared {
    /// Per-worker work-stealing deques of continuations.
    pub deques: Vec<Deque<FramePtr>>,
    /// Per-worker MPSC submission queues (no global queue, §III-D1).
    pub submissions: Vec<SubmissionQueue<FramePtr>>,
    /// Per-worker parkers (lazy scheduler sleep/wake).
    pub parkers: Vec<Parker>,
    /// Per-worker Eq. (6) victim samplers.
    pub samplers: Vec<AliasSampler>,
    /// Machine/NUMA model.
    pub topology: NumaTopology,
    /// Scheduler flavour (busy / lazy).
    pub scheduler: SchedulerKind,
    /// Event counters.
    pub metrics: Metrics,
    /// Pool shutdown flag.
    pub shutdown: AtomicBool,
    /// Workers currently executing tasks (lazy policy input).
    pub active: AtomicUsize,
    /// Workers currently parked.
    pub sleepers: AtomicUsize,
    /// Per-node count of awake (not parked) workers.
    pub awake_in_node: Vec<CachePadded<AtomicUsize>>,
    /// Per-worker "is parked" flags (for targeted wakeups).
    pub parked_flag: Vec<CachePadded<AtomicBool>>,
    /// First-stacklet capacity for worker stacks.
    pub first_stacklet: usize,
    /// CPU id of worker 0 — worker `i` pins to CPU `pin_offset + i`.
    /// Lets a sharded job server place each sub-pool on its own NUMA
    /// node's cores (see [`crate::service`]).
    pub pin_offset: usize,
}

impl Shared {
    /// Wake one parked worker, preferring `from`'s NUMA node. Cheap when
    /// nobody sleeps (single relaxed load) — called on the fork hot path.
    #[inline]
    pub fn wake_one(&self, from: usize) {
        if self.sleepers.load(Ordering::Relaxed) == 0 {
            return;
        }
        self.wake_one_slow(from);
    }

    #[cold]
    fn wake_one_slow(&self, from: usize) {
        let node = self.topology.node_of(from);
        let p = self.deques.len();
        // Same node first, then the rest.
        for w in (0..p).filter(|&w| self.topology.node_of(w) == node) {
            if self.try_wake(w) {
                return;
            }
        }
        for w in (0..p).filter(|&w| self.topology.node_of(w) != node) {
            if self.try_wake(w) {
                return;
            }
        }
    }

    fn try_wake(&self, w: usize) -> bool {
        if self.parked_flag[w]
            .compare_exchange(true, false, Ordering::AcqRel, Ordering::Relaxed)
            .is_ok()
        {
            self.parkers[w].notify();
            true
        } else {
            false
        }
    }

    /// Wake everyone (shutdown).
    pub fn wake_all(&self) {
        for p in &self.parkers {
            p.notify();
        }
    }
}

/// Builder for [`Pool`].
pub struct PoolBuilder {
    workers: usize,
    scheduler: SchedulerKind,
    topology: Option<NumaTopology>,
    first_stacklet: usize,
    seed: u64,
    pin_offset: usize,
}

impl PoolBuilder {
    fn new() -> Self {
        PoolBuilder {
            workers: crate::numa::available_cpus(),
            scheduler: SchedulerKind::Busy,
            topology: None,
            first_stacklet: crate::stack::FIRST_STACKLET,
            seed: 0x5EED,
            pin_offset: 0,
        }
    }

    /// Number of workers (default: available CPUs).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Scheduler flavour (default: busy).
    pub fn scheduler(mut self, s: SchedulerKind) -> Self {
        self.scheduler = s;
        self
    }

    /// Override the detected topology (e.g. the synthetic paper testbed).
    pub fn topology(mut self, t: NumaTopology) -> Self {
        self.topology = Some(t);
        self
    }

    /// First-stacklet capacity in bytes.
    pub fn first_stacklet(mut self, bytes: usize) -> Self {
        self.first_stacklet = bytes;
        self
    }

    /// RNG seed for victim selection (determinism in tests).
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Pin worker `i` to CPU `offset + i` instead of CPU `i`. Used by
    /// the sharded [`crate::service::JobServer`] to place each sub-pool
    /// on its own NUMA node's cores. Best-effort, like all pinning.
    pub fn pin_offset(mut self, offset: usize) -> Self {
        self.pin_offset = offset;
        self
    }

    /// Spawn the workers and return the pool.
    pub fn build(self) -> Pool {
        let p = self.workers;
        let topology = match self.topology {
            Some(t) => t.with_cores(p),
            None => NumaTopology::detect(p),
        };
        let samplers = if p > 1 {
            (0..p).map(|i| AliasSampler::new(&topology.victim_weights(i))).collect()
        } else {
            // Single worker: sampler unused; a uniform stub keeps the
            // types simple.
            vec![AliasSampler::new(&[1.0])]
        };
        let nodes = topology.nodes();
        let mut awake_in_node: Vec<CachePadded<AtomicUsize>> =
            (0..nodes).map(|_| CachePadded::new(AtomicUsize::new(0))).collect();
        for w in 0..p {
            *awake_in_node[topology.node_of(w)].get_mut() += 1;
        }
        let shared = Arc::new(Shared {
            deques: (0..p).map(|_| Deque::new()).collect(),
            submissions: (0..p).map(|_| SubmissionQueue::new()).collect(),
            parkers: (0..p).map(|_| Parker::new()).collect(),
            samplers,
            topology,
            scheduler: self.scheduler,
            metrics: Metrics::new(p),
            shutdown: AtomicBool::new(false),
            active: AtomicUsize::new(0),
            sleepers: AtomicUsize::new(0),
            awake_in_node,
            parked_flag: (0..p)
                .map(|_| CachePadded::new(AtomicBool::new(false)))
                .collect(),
            first_stacklet: self.first_stacklet,
            pin_offset: self.pin_offset,
        });
        let mut threads = Vec::with_capacity(p);
        for id in 0..p {
            let shared = Arc::clone(&shared);
            let seed = self.seed.wrapping_add(1 + id as u64).wrapping_mul(0x9E3779B9);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("rustfork-w{id}"))
                    .spawn(move || {
                        let mut w = super::worker::Worker::new(id, shared, seed);
                        w.run();
                    })
                    .expect("spawn worker"),
            );
        }
        Pool { shared, threads, next_submit: AtomicUsize::new(0) }
    }
}

/// A pool of continuation-stealing workers. Submit root tasks with
/// [`Pool::run`]; the pool shuts down (joining all threads) on drop.
pub struct Pool {
    shared: Arc<Shared>,
    threads: Vec<std::thread::JoinHandle<()>>,
    next_submit: AtomicUsize,
}

impl Pool {
    /// Start building a pool.
    pub fn builder() -> PoolBuilder {
        PoolBuilder::new()
    }

    /// A busy-scheduler pool with `n` workers.
    pub fn with_workers(n: usize) -> Pool {
        Self::builder().workers(n).build()
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.shared.deques.len()
    }

    /// Aggregate runtime counters.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Shared state (used by benches to inspect per-worker data).
    pub fn shared(&self) -> &Arc<Shared> {
        &self.shared
    }

    /// Run a root task to completion and return its result (blocking).
    pub fn run<C: Coroutine>(&self, task: C) -> C::Output {
        let handle = self.submit(task);
        handle.join()
    }

    /// Submit a root task; returns a handle to join later (or `.await`).
    /// Root tasks are distributed round-robin over the per-worker
    /// submission queues.
    pub fn submit<C: Coroutine>(&self, task: C) -> RootHandle<C::Output> {
        let (frame, handle) = self.new_root(task);
        let target = self.next_target();
        self.shared.submissions[target].push(frame);
        self.wake_target(target);
        handle
    }

    /// Submit a batch of root tasks with one wake sweep instead of a
    /// per-job `notify`, amortizing parker and flag traffic on the
    /// submission hot path. Frames are distributed round-robin (same
    /// counter as [`Self::submit`]) but enqueued per worker via
    /// [`SubmissionQueue::push_batch`] — a single tail exchange per
    /// (batch × worker) rather than per job. Handles are returned in
    /// input order.
    pub fn submit_batch<C: Coroutine>(
        &self,
        tasks: impl IntoIterator<Item = C>,
    ) -> Vec<RootHandle<C::Output>> {
        let p = self.workers();
        let mut groups: Vec<Vec<FramePtr>> = (0..p).map(|_| Vec::new()).collect();
        let mut handles = Vec::new();
        for task in tasks {
            let (frame, handle) = self.new_root(task);
            groups[self.next_target()].push(frame);
            handles.push(handle);
        }
        for (w, group) in groups.into_iter().enumerate() {
            if !group.is_empty() {
                self.shared.submissions[w].push_batch(group);
                self.wake_target(w);
            }
        }
        handles
    }

    /// Round-robin submission target.
    #[inline]
    fn next_target(&self) -> usize {
        self.next_submit.fetch_add(1, Ordering::Relaxed) % self.workers()
    }

    /// Wake `target` after pushing to its submission queue. The eager
    /// flag clear keeps `wake_one` from wasting its CAS on a worker that
    /// is already being woken; the latched parker closes the race with a
    /// concurrent park.
    #[inline]
    fn wake_target(&self, target: usize) {
        self.shared.parked_flag[target].store(false, Ordering::Release);
        self.shared.parkers[target].notify();
    }

    /// Allocate a root frame (stack + signal + result cell) for `task`.
    fn new_root<C: Coroutine>(&self, task: C) -> (FramePtr, RootHandle<C::Output>) {
        // The root gets a fresh stack that travels with the frame.
        let mut stack = SegmentedStack::with_first_capacity(self.shared.first_stacklet);
        // The signal is jointly owned: the handle holds one reference,
        // the frame a second (as a raw Arc clone, released by the worker
        // in the final awaitable). Joint ownership is load-bearing — a
        // waiter can observe `done` and free its side while the worker
        // is still inside `complete()` (parker notify, waker wake), so
        // single ownership through the handle would be a use-after-free.
        let signal = Arc::new(RootSignal::new());
        let result: Box<std::mem::MaybeUninit<C::Output>> =
            Box::new(std::mem::MaybeUninit::uninit());
        let result_ptr = Box::into_raw(result);
        let size = Frame::<C>::alloc_size();
        let mem = stack.alloc(size) as *mut Frame<C>;
        unsafe {
            mem.write(Frame {
                header: FrameHeader {
                    resume: super::worker::resume_shim::<C>,
                    parent: std::ptr::null_mut(),
                    stack: std::ptr::null_mut(), // patched below
                    alloc_size: size as u32,
                    kind: FrameKind::Root,
                    steals: 0,
                    join: JoinCounter::new(),
                    root_signal: Arc::into_raw(Arc::clone(&signal)),
                },
                out: result_ptr as *mut C::Output,
                task,
            });
        }
        let stack_ptr = Box::into_raw(stack);
        unsafe { (*(mem as *mut FrameHeader)).stack = stack_ptr };
        (
            FramePtr(mem as *mut FrameHeader),
            RootHandle { signal, result: result_ptr, joined: false },
        )
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.wake_all();
        for t in self.threads.drain(..) {
            // Keep waking: a worker may re-park between flag store and join.
            while !t.is_finished() {
                self.shared.wake_all();
                std::thread::yield_now();
            }
            let _ = t.join();
        }
    }
}

/// Join handle for a submitted root task.
///
/// Works both synchronously and asynchronously:
///
/// * [`RootHandle::join`] blocks the calling thread until completion;
/// * as a [`std::future::Future`], it registers its waker with the
///   root's [`RootSignal`] and resolves to the task's output when the
///   completing worker calls [`RootSignal::complete`]. Any executor
///   works; the crate ships a minimal one in [`crate::sync::block_on`].
///
/// The async contract: the result is produced exactly once (by `join`,
/// by the future's `Ready`, or by the blocking drop path), the worker's
/// Release store of `done` happens-after the result write, and polling
/// after completion panics (like `JoinHandle` misuse).
pub struct RootHandle<T> {
    signal: Arc<RootSignal>,
    result: *mut std::mem::MaybeUninit<T>,
    joined: bool,
}

unsafe impl<T: Send> Send for RootHandle<T> {}

impl<T> RootHandle<T> {
    /// Block until the task completes and take its result.
    pub fn join(mut self) -> T {
        self.signal.wait();
        unsafe { self.take_result() }
    }

    /// Non-blocking completion check.
    pub fn is_done(&self) -> bool {
        self.signal.is_done()
    }

    /// Take ownership of the completed result.
    ///
    /// # Safety
    /// The signal must have completed (`is_done()`), and the result must
    /// not have been taken yet (`!self.joined`).
    unsafe fn take_result(&mut self) -> T {
        debug_assert!(self.signal.is_done() && !self.joined);
        self.joined = true;
        let b = Box::from_raw(self.result);
        *b.assume_init()
    }
}

impl<T: Send> std::future::Future for RootHandle<T> {
    type Output = T;

    fn poll(
        self: std::pin::Pin<&mut Self>,
        cx: &mut std::task::Context<'_>,
    ) -> std::task::Poll<T> {
        // All fields are Unpin (Box / raw pointer / bool), so the struct
        // is Unpin and get_mut is safe.
        let this = self.get_mut();
        assert!(!this.joined, "RootHandle polled after completion");
        if this.signal.is_done() {
            return std::task::Poll::Ready(unsafe { this.take_result() });
        }
        this.signal.register_waker(cx.waker());
        // Re-check: completion may have raced between the first check
        // and the registration (complete() takes the same lock, so if it
        // missed our waker it had already set `done`).
        if this.signal.is_done() {
            std::task::Poll::Ready(unsafe { this.take_result() })
        } else {
            std::task::Poll::Pending
        }
    }
}

impl<T> Drop for RootHandle<T> {
    fn drop(&mut self) {
        if !self.joined {
            // Must wait: the worker writes through `result` and reads the
            // signal; both must stay alive until completion.
            self.signal.wait();
            unsafe {
                let b = Box::from_raw(self.result);
                // Drop the initialized value.
                drop(b.assume_init());
            }
        }
    }
}
