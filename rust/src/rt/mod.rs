//! The continuation-stealing runtime (paper §III-B).
//!
//! * [`worker::Worker`] — the per-thread execution engine: the resume
//!   trampoline (symmetric transfer) and the paper's Algorithm 3
//!   (fork-awaitable), Algorithm 4 (join-awaitable) and Algorithm 5
//!   (final-awaitable), including segmented-stack ownership transfer.
//! * [`pool::Pool`] — worker lifecycle, root-task submission, shutdown.
//! * [`root`] — the **fused root block**: signal + result + refcount +
//!   frame in one placement allocation on a recycled stack, making the
//!   steady-state submit→execute→complete→join cycle heap-allocation
//!   free.
//! * [`tune`] — feedback tuning: per-worker signals (job stack
//!   footprints, stacklet grows, migration miss ratios, park
//!   timestamps) sampled into plain-atomic EMA registers and fed back
//!   into stacklet sizing, migration hysteresis and wake routing.
//!
//! ## Ownership invariants (load-bearing; see the proofs in worker.rs)
//!
//! 1. A worker in its scheduler loop owns exactly one **empty** current
//!    stack.
//! 2. A frame's deque entry is consumed exactly once — by the hot-path
//!    pop of its child's final return, or by a steal (which increments
//!    the frame's steal counter).
//! 3. `signals == steals` per fork-join scope: every steal of a
//!    continuation leaves exactly one dangling child whose
//!    subtree-completion performs one failed-pop signal.
//! 4. At a frame's final return, the executing worker's current stack is
//!    the stack the frame was allocated on (re-established after every
//!    join by the stack-transfer rules).

pub mod pool;
pub mod root;
pub mod tune;
pub mod worker;

pub use pool::{Pool, PoolBuilder};
pub use worker::Worker;
