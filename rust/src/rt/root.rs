//! The **fused root block**: signal + result cell + refcount + root
//! frame in one placement allocation on a recycled segmented stack.
//!
//! Before this layer, every root submission performed four heap
//! allocations (`Box<SegmentedStack>`, its first stacklet,
//! `Arc<RootSignal>`, `Box<MaybeUninit<T>>`) and the handle/worker pair
//! freed them one by one — `O(1)·T_heap` per job where Eq. (5) promises
//! the heap term amortizes away. The fused block removes all four:
//!
//! ```text
//!   recycled stack (from the StackShelf)
//!   ┌──────────────────────────────────────────────────────────┐
//!   │ RootBlock<C>                                             │
//!   │ ┌──────────────┬──────────────────────┬────────────────┐ │
//!   │ │ Frame<C>     │ RootHot              │ MaybeUninit<T> │ │
//!   │ │ (header +    │ signal · refs(=2) ·  │ (result cell)  │ │
//!   │ │  out + task) │ base · shelf         │                │ │
//!   │ └──────────────┴──────────────────────┴────────────────┘ │
//!   └──────────────────────────────────────────────────────────┘
//! ```
//!
//! ## Lifecycle (who releases which half)
//!
//! The block starts with **two** refcount halves:
//!
//! * the **worker half** — released in the final awaitable, *after*
//!   [`RootSignal::complete`] has fired (so the signal outlives the
//!   parker notify + waker wake, preserving the use-after-free fix that
//!   previously required the `Arc`);
//! * the **handle half** — released by [`RootHandle`] when the result
//!   leaves the block (`join`, the future's `Ready`) or when the handle
//!   is dropped un-joined (which waits, then drops the result in place).
//!
//! Whichever release observes the count reach zero **disposes**: it runs
//! the signal's destructor, pops the block off its stack (restoring
//! `live == 0`) and recycles the stack through the [`StackShelf`] — so
//! in steady state the stack a job completed on is the stack the next
//! submission is built on, and neither side ever touches the allocator.
//!
//! [`RootHandle`]: crate::rt::pool::RootHandle
//! [`RootSignal::complete`]: crate::rt::pool::RootSignal::complete

use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use crate::frame::FrameHeader;
use crate::stack::{round_up, StackShelf};
use crate::task::{Coroutine, Frame};

use super::pool::{AbandonHook, DrainKind, RootSignal};

/// Kill-byte states (`RootHot::kill`). `LIVE` is the initial state; the
/// first `mark_kill` wins and later marks never overwrite it, so the
/// recorded cause is the *earliest* one (a job cancelled by its client
/// stays `Cancelled` even if its deadline also expires while queued).
pub(crate) const KILL_LIVE: u8 = 0;
pub(crate) const KILL_CANCELLED: u8 = 1;
pub(crate) const KILL_SHED: u8 = 2;
pub(crate) const KILL_EXPIRED: u8 = 3;

/// Monotonic microseconds since the first call in this process. Used as
/// the deadline clock: `0` is reserved as the "no deadline" sentinel, so
/// producers clamp computed deadlines to `>= 1`.
pub(crate) fn now_micros() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Pack a placement shard and a tenant id into the [`RootHot::tag`]
/// word carried to the pool's abandonment hook: shard in the low 32
/// bits, tenant in the high 32. Plain (tenant-less) submissions use
/// tenant 0, which decodes back to the pre-tenancy layout (`tag ==
/// shard`).
#[inline]
pub(crate) fn pack_tag(shard: usize, tenant: u32) -> u64 {
    (shard as u64 & 0xFFFF_FFFF) | ((tenant as u64) << 32)
}

/// The placement shard packed into a tag by [`pack_tag`].
#[inline]
pub(crate) fn tag_shard(tag: u64) -> usize {
    (tag & 0xFFFF_FFFF) as usize
}

/// The tenant id packed into a tag by [`pack_tag`].
#[inline]
pub(crate) fn tag_tenant(tag: u64) -> u32 {
    (tag >> 32) as u32
}

/// The type-erased hot part of a fused root block: everything the
/// submitter's handle and the completing worker share. Lives inside the
/// block's stack allocation, directly after the typed frame.
pub struct RootHot {
    signal: RootSignal,
    /// Two halves: worker + handle. The last release disposes the block
    /// and recycles its stack.
    refs: AtomicUsize,
    /// Set (exactly once, by the winning [`abandon`] call) when a
    /// workload panic abandoned this root. The disposer then
    /// quarantines the block's stack instead of recycling it — the root
    /// frame (and possibly abandoned ancestors of the panicked frame)
    /// are still allocated on it, and sibling strands of the job may
    /// still be running against it.
    abandoned: AtomicBool,
    /// Set by the clean-discard path ([`discard`]): the root was
    /// abandoned *before it ever ran*, so the block is the stack's only
    /// allocation and the stack can be recycled instead of quarantined.
    clean: AtomicBool,
    /// Set by the worker that first resumes this root. A started root
    /// must never be discarded at a queue boundary — its continuation
    /// can legally reappear in a steal (a root that forked gets its
    /// continuation stolen) while children are in flight. Exception:
    /// while `yielded` (below) is also set, the strand is suspended at a
    /// root-level safe point and discard becomes legal again.
    started: AtomicBool,
    /// Set while the strand is parked at a **root-level safe point**
    /// ([`crate::task::Step::Yield`] accepted by the migration hub):
    /// `signals == steals` holds, no child is in flight, and the fused
    /// block is its stack's only allocation — exactly the
    /// never-started shape, so queue-side discard (kill-byte checks at
    /// claim) is sound again. Cleared by the worker that resumes the
    /// capsule, which closes the discard window before any child can
    /// exist.
    yielded: AtomicBool,
    /// Kill byte: `KILL_LIVE` or the first `KILL_*` cause marked by a
    /// client cancel, the shed policy, or deadline expiry. Checked with
    /// one relaxed load at dequeue/steal/claim boundaries and at every
    /// child-frame fork boundary of a running strand.
    kill: AtomicU8,
    /// **Debt ledger** for the owed-signal handoff: how many of this
    /// job's frames are currently parked in join-word settlement mode
    /// (`JoinCounter::begin_settlement`), each waiting for its last
    /// stolen child to settle. Incremented by the dying owner at the
    /// flip, decremented by the settling child when it picks the unwind
    /// back up. Zero at quiescence; while non-zero the job's stacks may
    /// still be written through remote join pointers, so the capsule
    /// lanes and the clean-discard route must treat the job as live
    /// memory (they already do — settlement only arises on started,
    /// non-yielded roots — but the ledger makes the invariant checkable
    /// and is asserted by the chaos suite at quiescence).
    settling: AtomicUsize,
    /// Absolute deadline in [`now_micros`] ticks; `0` means none.
    deadline: AtomicU64,
    /// Monomorphized task destructor for the clean-discard path: drops
    /// the never-started task state in place without resuming it.
    discard_task: unsafe fn(*mut FrameHeader),
    /// Base of the whole block allocation (== the frame header), from
    /// which dispose reads the stack pointer and allocation size.
    base: *mut FrameHeader,
    /// Raw `Arc<StackShelf>` reference (the recycle route). Reconstituted
    /// and dropped by the disposer, so the shelf outlives every
    /// outstanding handle even after its pool is gone.
    shelf: *const StackShelf,
    /// Caller-supplied label carried from submission to the pool's
    /// abandonment hook (the sharded job server stores the placement
    /// shard here, so a panicked job's admission slot is released
    /// against the right shard even when the job migrated). Zero for
    /// plain submissions.
    tag: u64,
}

impl RootHot {
    /// Fresh hot part with both halves outstanding. Takes ownership of
    /// one raw `Arc<StackShelf>` reference.
    pub(crate) fn new(
        base: *mut FrameHeader,
        shelf: *const StackShelf,
        tag: u64,
        discard_task: unsafe fn(*mut FrameHeader),
    ) -> Self {
        RootHot {
            signal: RootSignal::new(),
            refs: AtomicUsize::new(2),
            abandoned: AtomicBool::new(false),
            clean: AtomicBool::new(false),
            started: AtomicBool::new(false),
            yielded: AtomicBool::new(false),
            kill: AtomicU8::new(KILL_LIVE),
            settling: AtomicUsize::new(0),
            deadline: AtomicU64::new(0),
            discard_task,
            base,
            shelf,
            tag,
        }
    }

    /// The completion signal (done flag + parker + waker slot).
    #[inline]
    pub fn signal(&self) -> &RootSignal {
        &self.signal
    }

    /// Record a kill cause. First mark wins; later marks (including
    /// racing ones) are ignored so the cause is stable once set.
    #[inline]
    pub(crate) fn mark_kill(&self, code: u8) {
        let _ = self
            .kill
            .compare_exchange(KILL_LIVE, code, Ordering::Relaxed, Ordering::Relaxed);
    }

    /// Current kill byte (`KILL_LIVE` if the job is live).
    #[inline]
    pub(crate) fn kill_code(&self) -> u8 {
        self.kill.load(Ordering::Relaxed)
    }

    /// Debt-ledger entry: a dying owner flipped one more of this job's
    /// frames into settlement mode. Pairs with [`Self::note_settled`].
    #[inline]
    pub(crate) fn note_handoff(&self) {
        self.settling.fetch_add(1, Ordering::Release);
    }

    /// Debt-ledger exit: a settling child finished one handed-off
    /// frame's deferred unwind.
    #[inline]
    pub(crate) fn note_settled(&self) {
        let prev = self.settling.fetch_sub(1, Ordering::Release);
        debug_assert!(prev > 0, "settlement ledger underflow");
    }

    /// Frames of this job currently in settlement mode (0 at
    /// quiescence; see the field docs).
    #[inline]
    pub(crate) fn settling(&self) -> usize {
        self.settling.load(Ordering::Acquire)
    }

    /// Set the absolute deadline (in [`now_micros`] ticks, `>= 1`).
    #[inline]
    pub(crate) fn set_deadline(&self, at_micros: u64) {
        self.deadline.store(at_micros.max(1), Ordering::Relaxed);
    }

    /// Absolute deadline, or `0` if none was set.
    #[inline]
    pub(crate) fn deadline(&self) -> u64 {
        self.deadline.load(Ordering::Relaxed)
    }

    /// Mark the root as started (first resume). After this, queue-side
    /// discard is off the table; cancellation is cooperative only.
    #[inline]
    pub(crate) fn mark_started(&self) {
        self.started.store(true, Ordering::Relaxed);
    }

    /// Whether any worker has started resuming this root.
    #[inline]
    pub(crate) fn started(&self) -> bool {
        self.started.load(Ordering::Relaxed)
    }

    /// Mark / clear the root as parked at a root-level safe point. Set
    /// (with `Release`, pairing with the claim-side `Acquire`) *before*
    /// the detaching worker publishes the capsule to the started lane;
    /// cleared by the resuming worker before the first post-claim step.
    #[inline]
    pub(crate) fn set_yielded(&self, v: bool) {
        self.yielded.store(v, Ordering::Release);
    }

    /// Whether the strand is suspended at a root-level safe point (see
    /// the field docs — started-but-yielded roots are discardable).
    #[inline]
    pub(crate) fn yielded(&self) -> bool {
        self.yielded.load(Ordering::Acquire)
    }

    /// Take an extra refcount reference (the shed-oldest registry holds
    /// one per tracked job so the `*const RootHot` stays valid until the
    /// registry prunes it).
    #[inline]
    pub(crate) fn retain(&self) {
        self.refs.fetch_add(1, Ordering::Relaxed);
    }

    /// The caller-supplied submission label (see the field docs). The
    /// job server packs the placement shard and tenant slot in here;
    /// the migration hub reads it back to account a started-capsule
    /// handoff against the right tenant.
    #[inline]
    pub(crate) fn tag(&self) -> u64 {
        self.tag
    }
}

/// The full typed layout of a fused root block. `repr(C)` so the frame
/// header sits at offset 0 — a `*mut RootBlock<C>` is also a valid
/// `*mut FrameHeader` (the same prefix rule every frame relies on).
#[repr(C)]
pub struct RootBlock<C: Coroutine> {
    /// The root task's frame (header first).
    pub frame: Frame<C>,
    /// Shared completion state.
    pub hot: RootHot,
    /// Where the root's `co_return` value lands (`frame.out` points
    /// here).
    pub result: MaybeUninit<C::Output>,
}

impl<C: Coroutine> RootBlock<C> {
    /// Post-monomorphization guard: the block is placement-allocated at
    /// [`crate::stack::ALIGN`], so an over-aligned `C`/`C::Output`
    /// (e.g. `#[repr(align(32))]`) would land misaligned — UB. Fail the
    /// build for such types instead (the pre-fusion code heap-boxed the
    /// result, which honored any alignment).
    const ALIGN_OK: () = assert!(
        std::mem::align_of::<RootBlock<C>>() <= crate::stack::ALIGN,
        "RootBlock over-aligned: task/output alignment exceeds the segmented-stack ALIGN",
    );

    /// Stack allocation size for the whole fused block.
    pub const fn alloc_size() -> usize {
        // Force the alignment guard to be evaluated for every C.
        #[allow(clippy::let_unit_value)]
        let _ = Self::ALIGN_OK;
        round_up(std::mem::size_of::<RootBlock<C>>())
    }
}

/// Release one refcount half. The last release disposes the block and
/// recycles its stack through the shelf.
///
/// # Safety
/// `hot` must point at a live `RootHot` inside a root block, and the
/// caller must own an un-released half. After this call the caller must
/// not touch the block (signal, result, frame) again.
pub(crate) unsafe fn release(hot: *const RootHot) {
    if (*hot).refs.fetch_sub(1, Ordering::Release) != 1 {
        return;
    }
    // Acquire the other side's writes (result store, waker traffic)
    // before tearing the block down.
    std::sync::atomic::fence(Ordering::Acquire);
    dispose(hot as *mut RootHot);
}

/// Worker-side abandonment after a workload panic: fire the signal in
/// **abandoned** mode (the result cell was never written — handles
/// panic on `join`/`poll` and release silently on drop) and release the
/// worker's half on the job's behalf. Reached for both submission- and
/// steal-originated strands: the panic handler walks the panicked
/// frame's parent chain to the root, so a panic on a thief abandons the
/// job's **remote** root too (the PR 2 containment hole). The root
/// provably has not completed and cannot complete later — its scope is
/// missing the panicked frame's signal/return — so the worker half is
/// still held and releasing it here is sound.
///
/// Idempotent: two strands of the same job can panic concurrently and
/// both walk to the same root; only the winner of the `abandoned` swap
/// fires the signal, runs the pool's abandonment `hook` (strictly
/// *before* the signal, mirroring the completion-hook ordering — the
/// job server's accounting is settled by the time `join` unblocks) and
/// releases the worker half. Returns whether this call won the swap, so
/// callers can keep their metric bumps exactly-once under kill storms.
///
/// The caller must **own the root frame**: either the old argument
/// holds (an owed upward signal is missing, so no other strand can ever
/// complete the root) or — on the owed-signal handoff path, which
/// *delivers* those signals — the dying strand's settlement walk must
/// have claimed the root frame itself. Abandoning a root another strand
/// can still complete would release the worker half twice.
///
/// # Safety
/// `hot` must be the root of the panicked strand's job, owned as
/// described above. The caller must not touch the block after this call
/// (the release may dispose it).
pub(crate) unsafe fn abandon(
    hot: *const RootHot,
    hook: Option<&AbandonHook>,
    reason: DrainKind,
) -> bool {
    if (*hot).abandoned.swap(true, Ordering::AcqRel) {
        return false; // another strand of this job already abandoned the root
    }
    if let Some(h) = hook {
        let tag = (*hot).tag;
        // Hook code is outside the runtime (job-server accounting); a
        // panic there must not unwind into panic containment itself.
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h(tag, reason)));
    }
    (*hot).signal.complete_abandoned();
    release(hot);
    true
}

/// Queue-side discard of a root that **never started** — or that is
/// suspended at a **root-level safe point** (`started && yielded`, the
/// migration hub's started-capsule lane): drop the task state in place,
/// fire the signal in abandoned mode and release the worker half —
/// without resuming the job. In both shapes the block is the stack's
/// only allocation, so the disposer can recycle the stack (the `clean`
/// flag below) instead of quarantining it, which is what keeps
/// cancel/shed allocation-free in steady state. The abandon `hook`
/// decodes the home shard/tenant from the block's tag, so accounting
/// lands on the placement shard even when the capsule's stack has
/// already left it.
///
/// Idempotent through the same `abandoned` swap as [`abandon`]; safe to
/// race with a concurrent handle-side `cancel` (that only marks the kill
/// byte) but **not** with execution — callers must hold exclusive frame
/// ownership (just popped/claimed it from a queue) and must have checked
/// `!started() || yielded()`.
///
/// # Safety
/// `hot` must be the live hot part of a root block whose frame the
/// caller exclusively owns and whose task is either never-resumed or
/// suspended at a root-level yield (dropping the coroutine state in
/// place is sound in both). The caller must not touch the block after
/// this call.
pub(crate) unsafe fn discard(hot: *const RootHot, hook: Option<&AbandonHook>, reason: DrainKind) {
    if (*hot).abandoned.swap(true, Ordering::AcqRel) {
        return;
    }
    // Safety net: record the cause even if the caller forgot to mark it
    // (first mark wins, so an existing mark is preserved).
    (*hot).mark_kill(match reason {
        DrainKind::Cancelled => KILL_CANCELLED,
        DrainKind::Shed => KILL_SHED,
        DrainKind::Expired => KILL_EXPIRED,
        DrainKind::Panic => KILL_CANCELLED,
    });
    // Drop the never-started task state. The monomorphized shim was
    // captured at block construction; a task destructor panic is
    // contained the same way hook panics are.
    let base = (*hot).base;
    let shim = (*hot).discard_task;
    let clean = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| shim(base))).is_ok();
    // Only a cleanly-destructed block may be recycled; a panicking drop
    // leaves the stack's contents suspect, so fall back to quarantine.
    (*hot).clean.store(clean, Ordering::Release);
    if let Some(h) = hook {
        let tag = (*hot).tag;
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| h(tag, reason)));
    }
    (*hot).signal.complete_abandoned();
    release(hot);
}

/// Monomorphized task destructor stored in [`RootHot::discard_task`]:
/// drops the `Frame<C>::task` of a never-started (or safe-point
/// suspended) root in place.
///
/// # Safety
/// `f` must be the header of a `Frame<C>` whose task is initialized, not
/// currently executing (never resumed, or suspended at a root-level
/// yield), and not yet dropped.
pub(crate) unsafe fn discard_shim<C: Coroutine>(f: *mut FrameHeader) {
    std::ptr::drop_in_place(std::ptr::addr_of_mut!((*(f as *mut Frame<C>)).task));
}

/// Tear down a fully-released root block: drop the signal state, pop the
/// block off its stack and hand the (now empty) stack to the shelf. A
/// **poisoned** stack (workload panic on the stack itself) or an
/// **abandoned** root (the root frame never completed, so it is still
/// allocated — possibly on a pristine stack owned by a remote victim)
/// still holds live frames above/at the block: deallocating would
/// violate FILO and free memory other strands of the job may still
/// touch. Such stacks are handed to the shelf's poison bin, which frees
/// them once every pool and root block sharing the shelf is gone.
unsafe fn dispose(hot: *mut RootHot) {
    let base = (*hot).base;
    let shelf_raw = (*hot).shelf;
    let stack = (*base).stack;
    let size = (*base).alloc_size as usize;
    // Read before dropping the hot part (the flags live inside it).
    let abandoned = (*hot).abandoned.load(Ordering::Acquire);
    // Tenant footprint register this job's stack observations feed
    // (slot 0 for plain submissions; ids past the register file clamp).
    let slot = crate::rt::tune::tenant_slot(tag_tenant((*hot).tag));
    // A clean discard ([`discard`]) destructed the never-started task in
    // place, so the block is still the stack's only allocation and the
    // normal dealloc + recycle route is sound — that is what keeps the
    // cancel/shed path allocation-free instead of bleeding quarantined
    // stacks.
    let clean = (*hot).clean.load(Ordering::Acquire);
    // The signal owns a mutex + possibly a registered waker clone; the
    // task state and the result were already consumed by the shim and
    // the handle respectively (neither exists on the abandoned path).
    std::ptr::drop_in_place(hot);
    let shelf = Arc::from_raw(shelf_raw);
    if (abandoned && !clean) || (*stack).is_poisoned() {
        shelf.quarantine(stack);
        return;
    }
    (*stack).dealloc(base as *mut u8, size);
    debug_assert!((*stack).is_empty(), "root stack must quiesce at dispose");
    if abandoned {
        // Discarded-before-start: the job never grew the stack, so its
        // (tiny) footprint would drag the adaptive-sizing estimate down.
        // Recycle without feeding the tuner.
        shelf.recycle(stack);
        return;
    }
    // Feedback signal for adaptive stacklet sizing (rt::tune): this
    // job's peak live bytes and stacklet-grow count on its root stack —
    // exactly one sample per job, taken at the moment the stack
    // quiesces, credited to the submitting tenant's footprint register.
    // Two relaxed atomics; the recycle below then trims (and, if the
    // tenant's learned hot size moved, reshapes) the stack.
    shelf.observe_root_quiesce_for(slot, (*stack).peak_live_bytes(), (*stack).grows_since_trim());
    shelf.recycle_for(slot, stack);
}
