//! The per-thread worker: trampoline + Algorithms 3, 4, 5.
//!
//! ## Why `signals == steals` (invariant 3)
//!
//! A frame `p`'s continuation enters the owner's deque once per fork.
//! Each entry is consumed either by the **hot-path pop** in the final
//! return of the very child whose fork pushed it (no signal is sent), or
//! by a **steal**. Stealing is FIFO from the top of the Chase-Lev deque,
//! so entries are stolen strictly oldest-first: if `p`'s entry is still
//! present when a child's final return pops, every entry pushed during
//! that child's subtree has already been consumed, hence the popped entry
//! *is* `p` — the pop either returns `p` or fails. Each steal of `p`
//! leaves exactly one child subtree dangling on the victim; wherever that
//! subtree's completion migrates (via nested join resumes), the
//! completing worker's deque is empty at that point (everything older
//! was stolen first, everything newer was consumed), so it performs
//! exactly one failed-pop **signal** on `p`. Therefore the number of
//! signals `p` must expect at its join equals the number of times it was
//! stolen during the scope.
//!
//! ## Why the executor owns `f.stack` at `f`'s final return (invariant 4)
//!
//! A frame is allocated on its creator's current stack, so the invariant
//! holds at birth. It can only break when the continuation is stolen —
//! but a stolen frame is fully strict and must join before returning, and
//! both join completion paths re-adopt the frame's stack: the arriving
//! parent adopts it when `arrive()` succeeds (Algorithm 4 lines 8–10) and
//! the last signalling child adopts it before resuming (Algorithm 5
//! lines 16–18).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use crate::frame::{FrameHeader, FrameKind, FramePtr, Transfer};
use crate::stack::SegmentedStack;
use crate::sync::{Backoff, XorShift64};
use crate::task::{Coroutine, Cx, Frame, StageKind, Step};

use super::pool::{DrainKind, ExternalPoll, Shared};
use super::root;

/// Hot-path event counters kept worker-local (plain increments) and
/// flushed to the shared atomics at strand boundaries — fork/call/pop
/// fire per task, and a relaxed `fetch_add` per event costs ~10 ns/task
/// (§Perf-L3 iteration 1: 34.0 → 24.3 ns). Rare-path counters (steals,
/// signals, sleeps) stay atomic so cross-worker invariants like
/// `signals == steals` remain exact at quiescence.
#[derive(Default)]
struct LocalCounters {
    forks: u64,
    calls: u64,
    pops: u64,
}

/// Bound on the per-worker stack free-list. Small: a worker only needs
/// spares to cover concurrently-suspended joins it is the victim of;
/// overflow drains to the shared shelf (which covers submission reuse).
const LOCAL_STACK_CAP: usize = 4;

/// Panic payload for the fork-boundary cancellation stop. The unwind is
/// contained by the same machinery as a workload panic; the distinct
/// payload type just keeps cancellation out of panic-message formatting.
struct CancelUnwind;

/// Per-thread worker state. Created on the worker thread by the pool.
pub struct Worker {
    /// Worker id == index into the shared deque/submission/parker arrays.
    pub id: usize,
    /// Shared pool state.
    pub shared: Arc<Shared>,
    /// Current segmented stack (exclusively owned). Empty whenever the
    /// worker sits in its scheduler loop (invariant 1).
    pub(crate) stack: *mut SegmentedStack,
    /// Bounded LIFO free-list of quiesced stacks (each empty and trimmed
    /// to its first stacklet). Replaces the old single `spare` slot so
    /// steal-heavy traffic stops churning the allocator; capacity is
    /// pre-reserved, so pushes never allocate.
    pub(crate) stacks: Vec<*mut SegmentedStack>,
    /// Child staged by `Cx::fork`/`Cx::call` awaiting dispatch.
    pub(crate) staged: *mut FrameHeader,
    pub(crate) staged_kind: StageKind,
    /// Victim-selection randomness.
    pub(crate) rng: XorShift64,
    /// Hot-path counters, flushed at strand boundaries.
    local: LocalCounters,
    /// Frame currently being resumed by the trampoline (null between
    /// strands). On a workload panic this is where the unwind started:
    /// panic containment walks its parent chain to find the job's root,
    /// so steal-originated strands can abandon a **remote** root.
    current: *mut FrameHeader,
    /// Hot part of the root the current strand belongs to, when the
    /// strand entered through a Root-kind frame (submission pop, spout
    /// claim, or a stolen root continuation); null otherwise and between
    /// strands. Read by the fork-boundary cancellation check — one
    /// relaxed load per fork, no pointer chasing.
    active_root: *const root::RootHot,
    /// Containment-walk scratch (drained deque entries / visited
    /// frames), retained across unwinds so the warm handoff-unwind
    /// path performs no heap allocation.
    settle_drained: Vec<*mut FrameHeader>,
    settle_visited: Vec<*mut FrameHeader>,
}

impl Worker {
    /// Build a worker (call on its own thread).
    pub(crate) fn new(id: usize, shared: Arc<Shared>, seed: u64) -> Self {
        let stack = Box::into_raw(SegmentedStack::with_first_capacity(
            shared.first_stacklet,
        ));
        Worker {
            id,
            shared,
            stack,
            stacks: Vec::with_capacity(LOCAL_STACK_CAP),
            staged: std::ptr::null_mut(),
            staged_kind: StageKind::Call,
            rng: XorShift64::new(seed),
            local: LocalCounters::default(),
            current: std::ptr::null_mut(),
            active_root: std::ptr::null(),
            settle_drained: Vec::with_capacity(8),
            settle_visited: Vec::with_capacity(16),
        }
    }

    /// Flush the worker-local hot-path counters to the shared metrics.
    #[inline]
    pub(crate) fn flush_counters(&mut self) {
        if self.local.forks | self.local.calls | self.local.pops != 0 {
            let c = self.shared.metrics.worker(self.id);
            c.forks.fetch_add(self.local.forks, Ordering::Relaxed);
            c.calls.fetch_add(self.local.calls, Ordering::Relaxed);
            c.pops.fetch_add(self.local.pops, Ordering::Relaxed);
            self.local = LocalCounters::default();
        }
    }

    // ----------------------------------------------------------------
    // Scheduler loop
    // ----------------------------------------------------------------

    /// Main loop: drain submissions, steal, idle per the configured
    /// scheduler (busy or lazy).
    pub(crate) fn run(&mut self) {
        let _ = crate::numa::pin_current_thread(self.shared.pin_offset + self.id);
        let mut backoff = Backoff::new();
        loop {
            debug_assert!(unsafe { (*self.stack).is_empty() }, "invariant 1");

            // 1. Own submission queue (root tasks, explicit scheduling).
            if let Some(FramePtr(f)) = self.shared.submissions[self.id].pop() {
                // Batched submissions leave more jobs behind us; on a
                // lazy pool, wake a sleeper now so the forks we are
                // about to publish get stolen while we drain the rest.
                if self.shared.scheduler == crate::sched::SchedulerKind::Lazy
                    && !self.shared.submissions[self.id].is_empty()
                {
                    self.shared.wake_one(self.id);
                }
                // Dequeue boundary: a cancelled/shed/expired root that
                // never started is discarded here — task dropped in
                // place, slot + stack recovered — instead of executed.
                if unsafe { self.discard_if_dead(f) } {
                    backoff.reset();
                    continue;
                }
                unsafe {
                    self.note_root_started(f);
                    self.adopt_stack((*f).stack);
                }
                self.enter_active();
                self.execute_guarded(f);
                self.exit_active();
                backoff.reset();
                continue;
            }

            // 1b. Admission-ordered ingress (the job server's per-shard
            // QoS class queues). Polled before the steal attempt so
            // admitted-but-queued jobs keep the same priority over
            // steals that direct submissions have — the dequeue-order
            // hook that makes fair queueing real. A claimed frame enters
            // execution exactly like a popped submission. A lost claim
            // (`Retry`) falls through to steal/idle — the claim winner,
            // the enqueuer's wake or the park backstop brings us back —
            // and is not counted as a migration miss (that metric is
            // spout-only).
            if let Some(source) = &self.shared.ingress {
                if let ExternalPoll::Job(job) = source.poll() {
                    let FramePtr(f) = job.frame;
                    // Dequeue boundary, same as the submission pop.
                    if unsafe { self.discard_if_dead(f) } {
                        backoff.reset();
                        continue;
                    }
                    unsafe {
                        self.note_root_started(f);
                        self.adopt_stack((*f).stack);
                    }
                    self.enter_active();
                    self.execute_guarded(f);
                    self.exit_active();
                    backoff.reset();
                    continue;
                }
            }

            if self.shared.shutdown.load(Ordering::Acquire) {
                // Drain any submission that raced with shutdown: with no
                // thieves left, strands complete inline (steals == 0 fast
                // paths), so executing here cannot block.
                while let Some(FramePtr(f)) = self.shared.submissions[self.id].pop() {
                    if unsafe { self.discard_if_dead(f) } {
                        continue;
                    }
                    unsafe {
                        self.note_root_started(f);
                        self.adopt_stack((*f).stack);
                    }
                    self.execute_guarded(f);
                }
                break;
            }

            // 2. Steal, victim per Eq. (6).
            if self.shared.deques.len() > 1 {
                let victim = self.shared.samplers[self.id].sample(&mut self.rng);
                match self.shared.deques[victim].steal() {
                    crate::deque::Steal::Success(FramePtr(f)) => {
                        // Steal boundary: one relaxed kill-byte load. In
                        // practice a stolen Root-kind frame is a started
                        // continuation (discard declines those), but the
                        // check keeps the boundary uniform and costs
                        // nothing against the steal's CAS.
                        if unsafe { self.discard_if_dead(f) } {
                            backoff.reset();
                            continue;
                        }
                        let counters = self.shared.metrics.worker(self.id);
                        counters.bump_steals();
                        if self.shared.topology.distance(self.id, victim) > 1 {
                            counters.bump_remote_steals();
                        }
                        // The thief owns the continuation now; count the
                        // steal on the frame (owner-exclusive field —
                        // ownership was transferred by the deque CAS).
                        unsafe {
                            (*f).steals += 1;
                            self.note_root_started(f);
                        }
                        self.enter_active();
                        // Propagate parallelism: if the victim still has
                        // work and someone is asleep, wake them.
                        if !self.shared.deques[victim].is_empty() {
                            self.shared.wake_one(self.id);
                        }
                        self.execute_guarded(f);
                        self.exit_active();
                        backoff.reset();
                        continue;
                    }
                    crate::deque::Steal::Retry => {
                        std::hint::spin_loop();
                        continue;
                    }
                    crate::deque::Steal::Empty => {
                        self.shared.metrics.worker(self.id).bump_steal_misses();
                    }
                }
            }

            // 2b. Cross-shard migration: before idling, try to claim a
            // diverted root from the pool's external source (the job
            // server's overflow spouts — own shard first, then siblings
            // nearest-first). A claimed frame enters execution exactly
            // like a popped submission, so the deque/stack invariants
            // are untouched.
            let claimed = match &self.shared.external {
                Some(source) => source.poll(),
                None => ExternalPoll::Empty,
            };
            match claimed {
                ExternalPoll::Job(job) => {
                    let FramePtr(f) = job.frame;
                    // Spout-claim boundary: a diverted root that died
                    // while queued in a spout is discarded, not executed
                    // (and not counted as a migration).
                    if unsafe { self.discard_if_dead(f) } {
                        backoff.reset();
                        continue;
                    }
                    if job.migrated {
                        let counters = self.shared.metrics.worker(self.id);
                        counters.bump_jobs_migrated();
                        if job.started {
                            // A re-homed started capsule: the root block
                            // and its stacklet chain crossed shards.
                            counters.bump_jobs_migrated_started();
                            counters.add_stacklets_adopted(job.adopted_stacklets);
                        }
                    }
                    unsafe {
                        self.note_root_started(f);
                        self.adopt_stack((*f).stack);
                    }
                    self.enter_active();
                    self.execute_guarded(f);
                    self.exit_active();
                    backoff.reset();
                    continue;
                }
                ExternalPoll::Retry => {
                    // Lost claim race or a producer push in flight: fall
                    // through to the idle policy rather than hot-spinning
                    // here — the winning claimer (or the producer's
                    // post-push wake, or the park backstop) brings us
                    // back, exactly like a transiently-empty submission
                    // queue.
                    self.shared.metrics.worker(self.id).bump_migration_misses();
                }
                ExternalPoll::Empty => {}
            }

            // 3. Idle policy.
            match self.shared.scheduler {
                crate::sched::SchedulerKind::Busy => backoff.snooze(),
                crate::sched::SchedulerKind::Lazy => {
                    if backoff.is_completed() {
                        crate::sched::lazy::idle(self);
                        backoff.reset();
                    } else {
                        backoff.snooze();
                    }
                }
            }
        }
    }

    /// Trampoline: resume frames via symmetric transfer until the strand
    /// is exhausted. Uses no OS stack per transfer (a loop, not
    /// recursion) — the analogue of C++ symmetric transfer. Tracks the
    /// in-flight frame in `self.current` so panic containment knows
    /// where an unwind started (one pointer store per resume).
    pub(crate) unsafe fn execute(&mut self, mut f: *mut FrameHeader) {
        loop {
            self.current = f;
            match ((*f).resume)(f, self) {
                Transfer::To(next) => f = next,
                Transfer::ToScheduler => break,
            }
        }
        self.current = std::ptr::null_mut();
    }

    /// Run a strand, containing workload panics: a panic unwinding out
    /// of a task's `step` poisons the worker's current stack (whose live
    /// frames are abandoned — see [`Self::on_workload_panic`]) instead
    /// of killing the worker thread. Zero-cost unless a panic actually
    /// occurs (`catch_unwind` only installs a landing pad).
    fn execute_guarded(&mut self, f: *mut FrameHeader) {
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            self.execute(f)
        }));
        if caught.is_err() {
            self.on_workload_panic();
        }
        // The strand is over; its root (if tracked) must not leak into
        // the next strand's fork-boundary cancellation checks.
        self.active_root = std::ptr::null();
    }

    /// Queue-boundary liveness check (dequeue / steal / spout claim):
    /// discard an **unstarted** root whose kill byte is set or whose
    /// deadline has expired, instead of executing it. One relaxed load
    /// on the live path (two when a deadline is armed); the discard
    /// itself drains through [`root::discard`] — task dropped in place,
    /// abandonment hook, signal, stack recycled — without ever resuming
    /// the job. Returns true when the frame was consumed.
    ///
    /// Started roots are never discarded here — with one exception: a
    /// Root-kind frame can legally reappear at the steal boundary as a
    /// *mid-run continuation* (a root that forked gets its continuation
    /// stolen) with children in flight — for those, cancellation is the
    /// cooperative fork-boundary check in [`Self::dispatch`]. The
    /// exception is a **yielded capsule** (`started && yielded`): a root
    /// suspended at a root-level safe point is back in the
    /// never-started shape — no children in flight, the block is its
    /// stack's only allocation — so queue-side discard is sound again.
    ///
    /// # Safety
    /// The caller must exclusively own `f` (just popped/claimed it).
    unsafe fn discard_if_dead(&mut self, f: *mut FrameHeader) -> bool {
        if (*f).kind != FrameKind::Root {
            return false;
        }
        let hot = (*f).root_hot;
        if hot.is_null() || ((*hot).started() && !(*hot).yielded()) {
            return false;
        }
        let mut code = (*hot).kill_code();
        if code == root::KILL_LIVE {
            let deadline = (*hot).deadline();
            if deadline == 0 || root::now_micros() < deadline {
                return false;
            }
            (*hot).mark_kill(root::KILL_EXPIRED);
            // Re-read: a racing cancel may have won the mark.
            code = (*hot).kill_code();
        }
        let counters = self.shared.metrics.worker(self.id);
        let reason = match code {
            root::KILL_SHED => {
                counters.bump_jobs_shed();
                DrainKind::Shed
            }
            root::KILL_EXPIRED => {
                counters.bump_deadline_expired();
                DrainKind::Expired
            }
            _ => {
                counters.bump_jobs_cancelled();
                DrainKind::Cancelled
            }
        };
        root::discard(hot, self.shared.on_abandon.as_deref(), reason);
        true
    }

    /// Record that the strand we are about to run enters through `f`:
    /// when `f` is a root, mark it started and clear any yielded flag
    /// (closing the queue-side discard window — for first starts and
    /// for re-homed capsules resuming after a root-level yield alike)
    /// and cache its hot part for the fork-boundary kill check. When `f`
    /// is a stolen **child** continuation, walk its parent chain to the
    /// job's root so steal-originated strands see kill bytes too — the
    /// walk is O(depth) against the steal's CAS and reads only immutable
    /// header fields of frames that provably outlive the scope (each is
    /// missing this subtree's signal/return).
    ///
    /// # Safety
    /// The caller must exclusively own `f` and be about to execute it.
    #[inline]
    unsafe fn note_root_started(&mut self, f: *mut FrameHeader) {
        if (*f).kind == FrameKind::Root {
            let hot = (*f).root_hot;
            if !hot.is_null() {
                (*hot).mark_started();
                (*hot).set_yielded(false);
                self.active_root = hot;
            }
            return;
        }
        let mut root = f;
        while !(*root).parent.is_null() {
            root = (*root).parent;
        }
        if (*root).kind == FrameKind::Root && !(*root).root_hot.is_null() {
            self.active_root = (*root).root_hot;
        }
    }

    /// Is the strand's job killed? Reads the cached hot part: the kill
    /// byte, and (when armed) the deadline — marking `KILL_EXPIRED`
    /// lazily on first observation past the deadline, exactly like the
    /// queue-boundary check. Caller must have checked `active_root` is
    /// non-null.
    #[inline]
    unsafe fn active_root_killed(&self) -> bool {
        let hot = self.active_root;
        let code = (*hot).kill_code();
        if code != root::KILL_LIVE {
            return true;
        }
        let deadline = (*hot).deadline();
        if deadline != 0 && root::now_micros() >= deadline {
            (*hot).mark_kill(root::KILL_EXPIRED);
            return true;
        }
        false
    }

    /// Contain a workload panic or a kill unwind (`CancelUnwind`). The
    /// current stack holds the dying strand's live frames; they are
    /// abandoned where they lie, but — unlike the pre-handoff design —
    /// their **steal debt is reconciled first** (the owed-signal
    /// handoff), so every *other* job, every live strand of *this* job
    /// and the pool itself keep running with exact accounting.
    ///
    /// The walk starts at the frame the unwind began in and climbs the
    /// parent chain, classifying each link:
    ///
    /// * **Owned** links — the called parent of a dying child, a fork
    ///   parent whose continuation entry we just drained from our own
    ///   deque, or a parent we claimed below — die with us. Each owned
    ///   frame with open steal debt is flipped into join-word
    ///   settlement mode ([`Self::settle_owned`]): its stolen children's
    ///   eventual completions settle the recorded debt (the settler
    ///   reclaims the frame's parked stack and the ledger entry) instead
    ///   of resuming a dead parent.
    /// * **Stolen** fork links (entry consumed by a thief) end our
    ///   ownership. On a *kill* unwind we deliver the dead child's owed
    ///   completion signal — `signals == steals` stays exact and the
    ///   thief's scope is never left waiting: `Pending` means the scope
    ///   stays alive elsewhere (its eventual join-resume runs the kill
    ///   checkpoint before any user code can read our unwritten output
    ///   slot); `LastResume` means we claimed the parent, so the walk
    ///   continues up through it; `LastSettle` means another dying
    ///   strand flipped it first and we are its settler. On a *plain
    ///   panic* no signal is delivered (the dead child's output slot was
    ///   never written and no kill byte guards the parent's join-resume
    ///   from reading it), so the scope above parks forever — the
    ///   pre-handoff containment semantics.
    ///
    /// The job's root is abandoned only when the walk **owns** it (or on
    /// the plain-panic path, where the withheld upward signal proves no
    /// other strand can ever complete it — the PR 2 argument). With
    /// signals delivered, a non-owned root either completes normally
    /// (kill raced completion — best effort) or is claimed and abandoned
    /// by a later dying strand; exactly one of the two happens.
    ///
    /// The strand's stack is **poisoned strictly before any counter
    /// flip** (the flip's `AcqRel` publishes the flag to settlers) and
    /// quarantined — never recycled — because its abandoned frames may
    /// still be referenced from outside. The worker continues on a
    /// pooled stack.
    #[cold]
    fn on_workload_panic(&mut self) {
        self.staged = std::ptr::null_mut();
        let start = self.current;
        self.current = std::ptr::null_mut();
        // Locate the job's root first (reads only immutable header
        // fields of frames that provably stay allocated: every ancestor
        // is missing a signal or return from this dying subtree, so none
        // can reach its final return and free itself).
        let mut root = start;
        unsafe {
            while !root.is_null() && !(*root).parent.is_null() {
                root = (*root).parent;
            }
        }
        let hot = unsafe {
            if !root.is_null() && (*root).kind == FrameKind::Root {
                (*root).root_hot
            } else {
                std::ptr::null()
            }
        };
        let killed = unsafe { !hot.is_null() && (*hot).kill_code() != root::KILL_LIVE };
        // Invariant 2 repair + steals stabilization: the strand's
        // unconsumed fork entries (its own continuations, possibly from
        // outer scopes of the same job) are still in our deque. Drain
        // them — a later job's hot-path pop must not receive a stale
        // parent, and a frame's `steals` is only stable for
        // `begin_settlement` once its entry is unreachable to thieves.
        // Entries lost to thieves racing this drain went through the
        // normal steal protocol: those parents are alive elsewhere and
        // are exactly the "stolen" links the walk below hands signals to.
        let mut drained = std::mem::take(&mut self.settle_drained);
        drained.clear();
        while let Some(FramePtr(f)) = self.shared.deques[self.id].pop() {
            drained.push(f);
        }
        // Poison strictly before abandoning or flipping any join word:
        // settlers and the last refcount release must observe the flag
        // and quarantine the stack instead of deallocating (or writing)
        // under the abandoned frames.
        unsafe { (*self.stack).poison() };
        self.shared.metrics.worker(self.id).bump_stacks_poisoned();
        let poisoned = self.stack;
        self.stack = self.fresh_stack();
        let root_stack =
            unsafe { if hot.is_null() { std::ptr::null_mut() } else { (*root).stack } };

        // The owed-signal handoff walk (see the method docs).
        let mut settled = std::mem::take(&mut self.settle_visited);
        settled.clear();
        let mut owns_root = false;
        unsafe {
            let mut a = start;
            while !a.is_null() {
                self.settle_owned(a, hot, poisoned, root_stack);
                settled.push(a);
                if (*a).kind == FrameKind::Root || (*a).parent.is_null() {
                    owns_root = (*a).kind == FrameKind::Root;
                    break;
                }
                let p = (*a).parent;
                match (*a).kind {
                    FrameKind::Root => unreachable!("root frames have no parent"),
                    FrameKind::Called => a = p,
                    FrameKind::Forked if drained.contains(&p) => a = p,
                    FrameKind::Forked if killed => {
                        // Deliver the dead child's owed signal (the
                        // failed-pop signal its final return would have
                        // sent) to the stolen parent.
                        self.shared.metrics.worker(self.id).bump_signals();
                        match (*p).join.signal_observe() {
                            crate::frame::SignalOutcome::Pending => break,
                            crate::frame::SignalOutcome::LastResume => {
                                // We won the parent's resume: its scope
                                // is complete (counter at zero, no
                                // future signal), so it dies with us
                                // un-flipped; the walk continues.
                                (*p).steals = 0;
                                a = p;
                            }
                            crate::frame::SignalOutcome::LastSettle => {
                                // Another dying strand flipped `p`; our
                                // signal settled its debt — run the
                                // settler duties and stop (that strand
                                // handled everything above).
                                self.finish_settlement(p, hot, poisoned, root_stack);
                                break;
                            }
                        }
                    }
                    FrameKind::Forked => break, // plain panic: park the scope above
                }
            }
            // Defensive sweep: a drained entry off the walked chain
            // would otherwise leave its stolen children resuming a dead
            // parent. (The chain argument says this is empty.)
            for &f in &drained {
                if !settled.contains(&f) {
                    debug_assert!(false, "drained entry off the dying strand's chain");
                    self.settle_owned(f, hot, poisoned, root_stack);
                }
            }
        }
        // Hand the scratch buffers back for the next unwind (capacity
        // retained — the warm path stays allocation-free).
        self.settle_drained = drained;
        self.settle_visited = settled;
        // Reclaim route for the poisoned stack: when the job's root
        // block lives on it, the block's disposer quarantines it after
        // the last refcount release. Otherwise no release path will
        // ever see this stack — hand it to the shelf's poison bin
        // directly.
        if root_stack != poisoned {
            unsafe { self.shared.shelf.quarantine(poisoned) };
        }
        if !hot.is_null() && (owns_root || !killed) {
            // A kill unwind is reported under its recorded cause
            // (metric + hook accounting), not as a workload failure;
            // the winner of the abandon swap bumps exactly once.
            let code = unsafe { (*hot).kill_code() };
            let reason = match code {
                root::KILL_CANCELLED => DrainKind::Cancelled,
                root::KILL_SHED => DrainKind::Shed,
                root::KILL_EXPIRED => DrainKind::Expired,
                _ => DrainKind::Panic,
            };
            let won = unsafe {
                crate::rt::root::abandon(hot, self.shared.on_abandon.as_deref(), reason)
            };
            if won {
                let counters = self.shared.metrics.worker(self.id);
                match reason {
                    DrainKind::Cancelled => counters.bump_jobs_cancelled(),
                    DrainKind::Shed => counters.bump_jobs_shed(),
                    DrainKind::Expired => counters.bump_deadline_expired(),
                    DrainKind::Panic => {}
                }
            }
        }
    }

    /// Flip one frame this dying strand owns into join-word settlement
    /// mode, recording its outstanding steal debt in the job's ledger.
    /// Zero-debt outcomes (no steals, or every signal already landed)
    /// make the owner its own settler: the frame's parked stack is
    /// reclaimed here and the ledger entry is undone immediately.
    ///
    /// Per-frame order is load-bearing: `retain` + `note_handoff`
    /// strictly before the `begin_settlement` flip, so a racing child
    /// that hits `LastSettle` always finds both the ledger entry and
    /// the block reference that keep `hot` (and the root stack under
    /// it) alive until its `release`.
    ///
    /// # Safety
    /// Caller must own `f` exclusively (its continuation unreachable to
    /// thieves) with `f.steals` stable, on the containment path.
    unsafe fn settle_owned(
        &mut self,
        f: *mut FrameHeader,
        hot: *const root::RootHot,
        poisoned: *mut SegmentedStack,
        root_stack: *mut SegmentedStack,
    ) {
        let steals = (*f).steals;
        if steals == 0 {
            self.reclaim_dead_stack((*f).stack, poisoned, root_stack);
            return;
        }
        if !hot.is_null() {
            (*hot).retain();
            (*hot).note_handoff();
        }
        let debt = (*f).join.begin_settlement(steals);
        if crate::fault::should_fire(crate::fault::FaultSite::HandoffStall) {
            // Park mid-handoff: settlers observe the ledger between the
            // debt record and the rest of the unwind.
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        if debt == 0 {
            self.reclaim_dead_stack((*f).stack, poisoned, root_stack);
            if !hot.is_null() {
                (*hot).note_settled();
                root::release(hot);
            }
        }
        // debt > 0: the last settling child reclaims f's stack and the
        // ledger entry (final_awaitable's LastSettle arm).
    }

    /// Settler duties for a frame flipped by *another* dying strand
    /// whose debt our containment walk just settled: reclaim its parked
    /// stack and undo that strand's ledger entry + block reference.
    unsafe fn finish_settlement(
        &mut self,
        p: *mut FrameHeader,
        hot: *const root::RootHot,
        poisoned: *mut SegmentedStack,
        root_stack: *mut SegmentedStack,
    ) {
        self.reclaim_dead_stack((*p).stack, poisoned, root_stack);
        if !hot.is_null() {
            (*hot).note_settled();
            root::release(hot);
        }
    }

    /// Reclaim a dead frame's stack on the containment path. Skips our
    /// own just-poisoned stack (quarantined by the caller), the root
    /// block's stack (the disposer's job), and stacks already poisoned
    /// by another dying strand (quarantined by it — the happens-before
    /// edge is that strand's `AcqRel` counter flip, which follows its
    /// poison write). Everything else is a parked stack holding exactly
    /// this abandoned frame, which no release path will ever see.
    unsafe fn reclaim_dead_stack(
        &mut self,
        s: *mut SegmentedStack,
        poisoned: *mut SegmentedStack,
        root_stack: *mut SegmentedStack,
    ) {
        if s.is_null() || s == poisoned || s == root_stack || (*s).is_poisoned() {
            return;
        }
        (*s).poison();
        self.shared.metrics.worker(self.id).bump_stacks_poisoned();
        self.shared.shelf.quarantine(s);
    }

    fn enter_active(&self) {
        self.shared.active.fetch_add(1, Ordering::SeqCst);
    }

    fn exit_active(&mut self) {
        self.flush_counters();
        self.shared.active.fetch_sub(1, Ordering::SeqCst);
    }

    // ----------------------------------------------------------------
    // Algorithm 3 — fork/call dispatch
    // ----------------------------------------------------------------

    /// Dispatch the staged child. For forks, expose the parent's
    /// continuation on our WSQ *after* its `step` returned (the paper
    /// pushes inside the awaitable, i.e. equally after the parent
    /// suspended) — a thief may resume the parent from this instant.
    #[inline]
    pub(crate) unsafe fn dispatch(&mut self, parent: *mut FrameHeader) -> Transfer {
        let child = self.staged;
        debug_assert!(!child.is_null(), "Step::Dispatch without a staged child");
        self.staged = std::ptr::null_mut();
        match self.staged_kind {
            StageKind::Fork => {
                // Fork-boundary kill checkpoint: one relaxed load on a
                // line the fork path already executes. A killed running
                // job (cancelled, shed, or past its deadline) stops here
                // — before exposing more work — by unwinding into the
                // panic-containment path, which reconciles the dying
                // frames' steal debt (owed-signal handoff, see
                // [`Self::on_workload_panic`]), abandons the root under
                // the matching reason, quarantines the strand's stack
                // and keeps the worker alive.
                //
                // **Every** fork boundary stops, child frames included:
                // the handoff flips each dying frame's join word into
                // settlement mode before the unwind, so stolen children
                // settle the recorded debt instead of resuming a dead
                // parent — `signals == steals` stays exact (asserted by
                // the chaos suite). Best-effort by design: strands that
                // never fork again run to completion.
                if !self.active_root.is_null() && self.active_root_killed() {
                    std::panic::panic_any(CancelUnwind);
                }
                self.shared.deques[self.id].push(FramePtr(parent));
                self.local.forks += 1;
                // Newly stealable work: wake a sleeper if any. Busy
                // pools never park, so skip even the relaxed sleeper
                // load there (§Perf-L3 iteration 4).
                if self.shared.scheduler == crate::sched::SchedulerKind::Lazy {
                    self.shared.wake_one(self.id);
                }
            }
            StageKind::Call => {
                self.local.calls += 1;
            }
        }
        Transfer::To(child)
    }

    // ----------------------------------------------------------------
    // Algorithm 4 — join
    // ----------------------------------------------------------------

    /// `co_await join`.
    #[inline]
    pub(crate) unsafe fn join_awaitable(&mut self, h: *mut FrameHeader) -> Transfer {
        let steals = (*h).steals;
        if steals == 0 {
            // Fast path: continuation never stolen → every child completed
            // locally (their hot-path pops returned us). No atomics.
            return Transfer::To(h);
        }
        // Read everything we need *before* the linearization point.
        let h_stack = (*h).stack;
        if (*h).join.arrive(steals) {
            // All dangling children already signalled: continue without
            // suspending, adopting h's stack (Alg. 4 lines 8–10).
            (*h).steals = 0;
            self.adopt_stack(h_stack);
            // Join-resume kill checkpoint (see final_awaitable's
            // LastResume arm): a killed job's dead children may have
            // signalled without writing their outputs, so the scope
            // must die before its post-join code runs. The scope is
            // settled (steals zeroed, counter balanced), so the
            // containment walk starts clean at `h`.
            if !self.active_root.is_null() && self.active_root_killed() {
                std::panic::panic_any(CancelUnwind);
            }
            Transfer::To(h)
        } else {
            // Suspend; the last signalling child resumes h. After
            // `arrive` fails we may not touch *h. If our current stack is
            // h's stack it must stay with h (h's frame lives there);
            // detach and take a fresh one.
            if self.stack == h_stack {
                self.stack = self.fresh_stack();
            } else {
                debug_assert!((*self.stack).is_empty());
            }
            Transfer::ToScheduler
        }
    }

    // ----------------------------------------------------------------
    // Algorithm 5 — final awaitable (cooperative return)
    // ----------------------------------------------------------------

    /// `co_return` epilogue. The typed shim has already written the
    /// output slot and dropped the task state; here we deallocate the
    /// frame and transfer control per the paper.
    pub(crate) unsafe fn final_awaitable(&mut self, h: *mut FrameHeader) -> Transfer {
        // Read all header fields before deallocation.
        let parent = (*h).parent;
        let kind = (*h).kind;
        let size = (*h).alloc_size as usize;
        debug_assert_eq!(self.stack, (*h).stack, "invariant 4");

        if kind == FrameKind::Root {
            // Output was written by the shim; publish completion (flush
            // first so `pool.metrics()` right after `run()` sees this
            // strand's counts).
            self.flush_counters();
            self.shared.metrics.worker(self.id).bump_roots();
            let hot = (*h).root_hot;
            debug_assert!(!hot.is_null(), "root frame without a fused block");
            // The strand is finishing; drop the cancellation cache
            // before the release below can dispose the block.
            self.active_root = std::ptr::null();
            // The fused root block is NOT deallocated here: it stays
            // live on this stack until both refcount halves release
            // (`rt::root`). Detach the stack first — whichever release
            // is last will pop the block and recycle it — and continue
            // on a pooled stack.
            self.stack = self.fresh_stack();
            // The worker's half keeps the block alive through
            // `complete()` — parker notify + async waker — even when the
            // submitter observes `done` and releases its half
            // concurrently (the use-after-free the old Arc guarded
            // against).
            (*hot).signal().complete();
            crate::rt::root::release(hot);
            return Transfer::ToScheduler;
        }

        (*self.stack).dealloc(h as *mut u8, size);

        match kind {
            FrameKind::Root => unreachable!("handled above"),
            FrameKind::Called => {
                // Resolved at compile time in libfork; here the branch is
                // predictable. Resume the caller directly.
                Transfer::To(parent)
            }
            FrameKind::Forked => {
                // Hot path (Alg. 5 line 10): reclaim the parent from our
                // own deque. By invariant 2 the popped entry is `parent`.
                if let Some(FramePtr(p)) = self.shared.deques[self.id].pop() {
                    debug_assert_eq!(p, parent, "invariant 2");
                    self.local.pops += 1;
                    return Transfer::To(parent);
                }
                // Implicit join (parent's continuation was stolen). Read
                // the parent's stack before the signal linearizes.
                let p_stack = (*parent).stack;
                self.shared.metrics.worker(self.id).bump_signals();
                if crate::fault::should_fire(crate::fault::FaultSite::JoinRace) {
                    // Widen the window between a dying owner's
                    // settlement flip and this completion signal.
                    std::thread::sleep(std::time::Duration::from_micros(100));
                }
                match (*parent).join.signal_observe() {
                    crate::frame::SignalOutcome::LastResume => {
                        // Last joiner: the parent's resume is ours.
                        (*parent).steals = 0;
                        // Join-resume kill checkpoint: once a job is
                        // killed, dead children may have signalled this
                        // scope without writing their output slots, so
                        // the parent must die *here* — before its post-
                        // join user code can read them. We own the
                        // parent (last signal won), so the containment
                        // walk settles it and its ancestors.
                        if !self.active_root.is_null() && self.active_root_killed() {
                            self.current = parent;
                            std::panic::panic_any(CancelUnwind);
                        }
                        // Resume it, adopting its stack (Alg. 5
                        // lines 16–18).
                        self.adopt_stack(p_stack);
                        return Transfer::To(parent);
                    }
                    crate::frame::SignalOutcome::LastSettle => {
                        // The parent was abandoned mid-scope (owed-
                        // signal handoff) and our completion settled
                        // its recorded debt: continue the dead owner's
                        // deferred unwind instead of resuming it.
                        return self.settle_abandoned(parent, p_stack);
                    }
                    crate::frame::SignalOutcome::Pending => {}
                }
                // Not last. If we hold the parent's stack (we are the
                // original victim), release it to the future resumer
                // (Alg. 5 lines 20–21) and take a fresh one.
                if self.stack == p_stack {
                    self.stack = self.fresh_stack();
                } else {
                    debug_assert!((*self.stack).is_empty());
                }
                Transfer::ToScheduler
            }
        }
    }

    /// Continue a dead owner's deferred unwind: the completing child's
    /// signal just hit `LastSettle` on an abandoned parent (flipped by
    /// [`Self::settle_owned`]). Exactly one child per flipped frame gets
    /// here (the counter parks at `-SETTLE_BIAS` and no further signal
    /// arrives), so the settler duties run once: park-reclaim the dead
    /// parent's stack and undo the owner's ledger entry + block
    /// reference (whose `release` — the last one, once the handle and
    /// worker halves are gone — frees the fused root block through the
    /// existing abandon path).
    ///
    /// The parent-chain walk reads only immutable header fields; every
    /// ancestor is either live (its scope is missing a signal/return
    /// from some strand, so it cannot free itself) or abandoned on a
    /// poisoned/quarantined stack that the shelf keeps allocated, and
    /// the ledger reference taken at the flip keeps the root block (and
    /// the root stack under it) alive until our `release` below.
    ///
    /// # Safety
    /// Caller observed `LastSettle` on `parent` whose stack is
    /// `p_stack`; `parent` is dead and this worker is its unique
    /// settler.
    #[cold]
    unsafe fn settle_abandoned(
        &mut self,
        parent: *mut FrameHeader,
        p_stack: *mut SegmentedStack,
    ) -> Transfer {
        let mut root = parent;
        while !(*root).parent.is_null() {
            root = (*root).parent;
        }
        let hot = if (*root).kind == FrameKind::Root {
            (*root).root_hot
        } else {
            std::ptr::null()
        };
        let root_stack =
            if hot.is_null() { std::ptr::null_mut() } else { (*root).stack };
        // If we are the original victim still holding the dead parent's
        // stack, detach from it before reclaiming (Alg. 5 lines 20–21
        // shape: the stack stays with the parked frame).
        if self.stack == p_stack {
            self.stack = self.fresh_stack();
        } else {
            debug_assert!((*self.stack).is_empty());
        }
        self.finish_settlement(parent, hot, std::ptr::null_mut(), root_stack);
        Transfer::ToScheduler
    }

    // ----------------------------------------------------------------
    // Root-level safe point (Step::Yield) — started-capsule detach
    // ----------------------------------------------------------------

    /// Cooperative safe point: decide whether the yielding strand should
    /// be re-homed. Returns `Some(ToScheduler)` when the frame was
    /// detached as a started-job capsule (root block + stack lease,
    /// pointer handoff — no byte copying) and handed to the pool's
    /// external source, **or** suspended at the yield awaiting its
    /// scope's outstanding signals (debt reconciliation below); `None`
    /// when the yield is a no-op and the caller should keep stepping
    /// the task.
    ///
    /// The detach is legal only at a **root-level** safe point, where
    /// the capsule is provably self-contained:
    ///
    /// - `h` is the job's root with its steal debt **settled**: a yield
    ///   inside a fork scope with `h.steals != 0` first arrives at the
    ///   scope's join word early. If every dangling child has already
    ///   signalled, the scope is settled on the spot (`steals` reset,
    ///   outputs all written — the later explicit join takes the
    ///   `steals == 0` fast path) and the detach checks proceed.
    ///   Otherwise the strand **suspends at the yield** and the last
    ///   signalling child resumes it there — exactly the join suspend
    ///   shape, which is what lets `drain_shard` and capsule detach
    ///   stop waiting on long forking phases.
    /// - No child is staged (the task yielded between phases, not
    ///   mid-dispatch).
    /// - The worker still runs on the root's own stack and the root
    ///   block is that stack's **only live allocation** — child frames
    ///   from completed scopes have all popped — so the stacklet chain
    ///   travels with the frame and nothing else does.
    ///
    /// Cost when the system is balanced: the pre-checks plus one
    /// `wants_started` call (a couple of relaxed loads), no state
    /// changes — the early-arrive fires only when the source actually
    /// wants the capsule, so live mid-scope yields stay free. The
    /// [`crate::fault::FaultSite::SafePointStall`] site declines the
    /// yield once, modelling a delayed safe point.
    ///
    /// # Safety
    /// Caller is the trampoline resuming `h`; the strand is suspended at
    /// the yield and owns its stack.
    pub(crate) unsafe fn yield_root(&mut self, h: *mut FrameHeader) -> Option<Transfer> {
        if (*h).kind != FrameKind::Root {
            return None;
        }
        let hot = (*h).root_hot;
        if hot.is_null() {
            return None;
        }
        // Kill checkpoint: a yield is a strand boundary just like a
        // fork; a killed job (cancelled / shed / past deadline) stops
        // here through the same contained unwind — with any open steal
        // debt handed off by the containment walk.
        if !self.active_root.is_null() && self.active_root_killed() {
            std::panic::panic_any(CancelUnwind);
        }
        debug_assert!(self.staged.is_null(), "yield with a staged child");
        // Pool shutdown: the server's drop-drain loops may already have
        // run, so a capsule detached now would land in a lane nobody
        // drains — a stranded handle. Finish the job in place instead.
        if self.shared.shutdown.load(Ordering::Acquire) {
            return None;
        }
        let wants = match &self.shared.external {
            Some(s) => s.wants_started(),
            None => return None,
        };
        if !wants {
            return None;
        }
        // Debt reconciliation (mid-scope yield): settle or suspend, see
        // the method docs. Only paid under demand (`wants` above).
        if (*h).steals != 0 {
            let steals = (*h).steals;
            let h_stack = (*h).stack;
            if !(*h).join.arrive(steals) {
                // Outstanding signals: park the strand at the yield.
                // The last signalling child resumes the task here (and
                // resets `steals`), with every output written.
                self.flush_counters();
                if self.stack == h_stack {
                    self.stack = self.fresh_stack();
                } else {
                    debug_assert!((*self.stack).is_empty());
                }
                self.active_root = std::ptr::null();
                return Some(Transfer::ToScheduler);
            }
            (*h).steals = 0;
            self.adopt_stack(h_stack);
        }
        if self.stack != (*h).stack
            || (*self.stack).live_bytes() != (*h).alloc_size as usize
        {
            // Not self-contained (completed child frames still live, or
            // a join left us on a different stack): free no-op.
            return None;
        }
        if crate::fault::should_fire(crate::fault::FaultSite::SafePointStall) {
            return None;
        }
        // Detach. Publish `yielded` first (Release) so a claimer that
        // sees the capsule also sees the safe-point shape; flush local
        // counters so metrics snapshots taken while the capsule is in
        // flight stay exact.
        self.flush_counters();
        (*hot).set_yielded(true);
        let capsule = self.stack;
        self.stack = self.fresh_stack();
        let prev_root = self.active_root;
        self.active_root = std::ptr::null();
        let source = Arc::clone(self.shared.external.as_ref().unwrap());
        match source.offer_started(FramePtr(h)) {
            None => Some(Transfer::ToScheduler),
            Some(FramePtr(back)) => {
                // wants/offer race: the source declined after all.
                // Reattach and keep running the strand at home.
                debug_assert_eq!(back, h, "offer_started returned a different frame");
                let spare = self.stack;
                self.stack = capsule;
                self.release_stack(spare);
                self.active_root = prev_root;
                (*hot).set_yielded(false);
                None
            }
        }
    }

    // ----------------------------------------------------------------
    // Explicit scheduling (§III-D1)
    // ----------------------------------------------------------------

    /// Migrate `h` (with its stack) to `target`'s submission queue.
    pub(crate) unsafe fn schedule_on(
        &mut self,
        h: *mut FrameHeader,
        target: usize,
    ) -> Transfer {
        assert!(target < self.shared.submissions.len(), "no such worker {target}");
        debug_assert_eq!(
            self.stack,
            (*h).stack,
            "ScheduleOn is only legal outside fork-join scopes"
        );
        // The stack travels with the frame; take a fresh one for ourselves.
        self.stack = self.fresh_stack();
        self.shared.submissions[target].push(FramePtr(h));
        // Full submission wake, not a bare notify: it also clears the
        // target's parked flag, stamp and mask bit, so a pinned
        // reschedule cannot leave a stale "parked" routing entry on the
        // worker it just woke (the wake-path stale-stamp audit).
        self.shared.wake_submission_target(target);
        Transfer::ToScheduler
    }

    // ----------------------------------------------------------------
    // Stack ownership plumbing
    // ----------------------------------------------------------------

    /// Adopt `target` as the current stack, releasing our (empty) one.
    #[inline]
    pub(crate) unsafe fn adopt_stack(&mut self, target: *mut SegmentedStack) {
        if self.stack != target {
            debug_assert!((*self.stack).is_empty(), "released stacks must be empty");
            self.release_stack(self.stack);
            self.stack = target;
        }
    }

    /// Take a quiesced stack: local free-list first (LIFO — warmest
    /// first), then the shared shelf, then (pool-miss) the allocator.
    #[inline]
    pub(crate) fn fresh_stack(&mut self) -> *mut SegmentedStack {
        let counters = self.shared.metrics.worker(self.id);
        if let Some(s) = self.stacks.pop() {
            counters.bump_stack_pool_hits();
            return s;
        }
        if let Some(s) = self.shared.shelf.pop() {
            counters.bump_stack_pool_hits();
            return s;
        }
        counters.bump_stack_pool_misses();
        // Pool miss: with adaptive sizing on, thief-side stacks are also
        // born at the learned hot size (a stolen subtree can be as deep
        // as the job that taught the tuner); otherwise the configured
        // first-stacklet capacity, as before.
        Box::into_raw(SegmentedStack::with_first_capacity(
            self.shared.shelf.hot_first_capacity(self.shared.first_stacklet),
        ))
    }

    /// Recycle an empty stack: trim to its first stacklet and push onto
    /// the local free-list; overflow drains to the shared shelf (which
    /// frees past its own bound). Poisoned stacks are leaked — their
    /// abandoned frames may still be referenced (defensive: the panic
    /// path leaks before this can see one).
    ///
    /// Local-list stacks follow the same adaptive-sizing rule as the
    /// shelf: a thief's next `fresh_stack` hit may host a **stolen deep
    /// subtree**, so a cold (pre-warmup) stack cycling through the LIFO
    /// would re-pay the geometric growth chain on every steal. The
    /// reshape fires only while the learned hot size is moving, so the
    /// steady state stays allocation-free.
    #[inline]
    unsafe fn release_stack(&mut self, s: *mut SegmentedStack) {
        // Poison check first: a poisoned stack still holds abandoned
        // frames, so the emptiness assert below would abort (in debug)
        // exactly where the defensive leak should run instead.
        if (*s).is_poisoned() {
            return;
        }
        debug_assert!((*s).is_empty(), "released stacks must be empty");
        if self.stacks.len() < LOCAL_STACK_CAP {
            (*s).trim();
            if let Some(target) =
                self.shared.shelf.tuner().reshape_target((*s).first_capacity())
            {
                (*s).reshape_first(target);
            }
            self.stacks.push(s);
        } else {
            self.shared.shelf.recycle(s);
        }
    }
}

impl Drop for Worker {
    fn drop(&mut self) {
        unsafe {
            debug_assert!(
                (*self.stack).is_empty() || (*self.stack).is_poisoned(),
                "worker exited with live frames"
            );
            if !(*self.stack).is_poisoned() {
                drop(Box::from_raw(self.stack));
            }
            for s in self.stacks.drain(..) {
                drop(Box::from_raw(s));
            }
        }
    }
}

/// Monomorphized resume entry: run one `step()` of the typed task and
/// apply the matching awaitable. Stored in every frame header.
pub unsafe fn resume_shim<C: Coroutine>(
    h: *mut FrameHeader,
    w: &mut Worker,
) -> Transfer {
    let frame = h as *mut Frame<C>;
    loop {
        let step = {
            let mut cx = Cx { worker: w, frame: h };
            (*frame).task.step(&mut cx)
        };
        match step {
            Step::Dispatch => return w.dispatch(h),
            Step::Join => {
                let t = w.join_awaitable(h);
                // Join fast path resumes this same frame: loop here
                // instead of bouncing through the trampoline's indirect
                // call (§Perf-L3 iteration 2).
                if t == Transfer::To(h) {
                    continue;
                }
                return t;
            }
            Step::Return(v) => {
                // co_return: write the result through the parent's slot,
                // then destroy the task state, then run the final
                // awaitable.
                let out = (*frame).out;
                if !out.is_null() {
                    out.write(v);
                }
                std::ptr::drop_in_place(&mut (*frame).task);
                return w.final_awaitable(h);
            }
            Step::ScheduleOn(target) => return w.schedule_on(h, target),
            Step::Yield => {
                // Root-level safe point: either the strand detaches as a
                // started-job capsule (rare — only under demand) or the
                // yield is free and we keep stepping in place.
                if let Some(t) = w.yield_root(h) {
                    return t;
                }
                continue;
            }
        }
    }
}
