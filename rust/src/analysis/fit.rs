//! Eq. (17) power-law fitting: `MRSS ≈ a + b·M₁·Pⁿ`.
//!
//! The model is linear in `(a, b)` for a fixed exponent `n`, so we solve
//! the 2×2 normal equations on a dense grid of `n` and pick the global
//! SSE minimizer — deterministic, derivative-free, and easily accurate
//! to the ±0.01 the paper's Table II reports. The quoted error is the
//! 1-σ estimate from the local curvature of the SSE profile in `n`
//! (the paper estimates errors "from the fit's covariance matrix").

/// Result of a power-law fit.
#[derive(Debug, Clone, Copy)]
pub struct PowerLawFit {
    /// Constant offset `a` (bytes).
    pub a: f64,
    /// Coefficient `b` (dimensionless; multiplies `M₁·Pⁿ`).
    pub b: f64,
    /// Exponent `n`.
    pub n: f64,
    /// 1-σ error on `n` from the SSE curvature.
    pub n_err: f64,
    /// Residual sum of squares at the optimum.
    pub sse: f64,
}

/// Fit `y ≈ a + b·m1·x^n` over samples `(x = P, y = peak bytes)`.
///
/// `m1` is the single-worker footprint (the paper normalizes `b` by
/// `M₁`). Requires ≥ 3 samples and positive `x`.
pub fn fit_power_law(xs: &[f64], ys: &[f64], m1: f64) -> PowerLawFit {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 3, "need at least 3 samples for a 3-parameter fit");
    assert!(m1 > 0.0);

    let sse_at = |n: f64| -> (f64, f64, f64) {
        // Least squares for y = a + b * (m1 * x^n): linear in (a, b).
        let k = xs.len() as f64;
        let mut s_u = 0.0; // Σ u_i  with u_i = m1·x^n
        let mut s_uu = 0.0;
        let mut s_y = 0.0;
        let mut s_uy = 0.0;
        for (&x, &y) in xs.iter().zip(ys) {
            let u = m1 * x.powf(n);
            s_u += u;
            s_uu += u * u;
            s_y += y;
            s_uy += u * y;
        }
        let det = k * s_uu - s_u * s_u;
        let (a, b) = if det.abs() < 1e-30 {
            (s_y / k, 0.0)
        } else {
            ((s_y * s_uu - s_u * s_uy) / det, (k * s_uy - s_u * s_y) / det)
        };
        let mut sse = 0.0;
        for (&x, &y) in xs.iter().zip(ys) {
            let r = y - (a + b * m1 * x.powf(n));
            sse += r * r;
        }
        (sse, a, b)
    };

    // Coarse-to-fine grid over n ∈ [-0.5, 2.5].
    let mut best = (f64::INFINITY, 0.0, 0.0, 0.0); // (sse, n, a, b)
    let mut lo = -0.5;
    let mut hi = 2.5;
    for _ in 0..4 {
        let steps = 200;
        let dx = (hi - lo) / steps as f64;
        for i in 0..=steps {
            let n = lo + i as f64 * dx;
            let (sse, a, b) = sse_at(n);
            if sse < best.0 {
                best = (sse, n, a, b);
            }
        }
        lo = best.1 - dx;
        hi = best.1 + dx;
    }
    let (sse, n, a, b) = best;

    // 1-σ error from the curvature of the SSE profile:
    // var(n) ≈ 2·σ²/ (d²SSE/dn²), σ² = SSE/(k-3).
    let h = 1e-3;
    let (s_plus, _, _) = sse_at(n + h);
    let (s_minus, _, _) = sse_at(n - h);
    let curv = (s_plus - 2.0 * sse + s_minus) / (h * h);
    let dof = (xs.len() as f64 - 3.0).max(1.0);
    let sigma2 = sse / dof;
    let n_err = if curv > 0.0 { (2.0 * sigma2 / curv).sqrt() } else { f64::NAN };

    PowerLawFit { a, b, n, n_err, sse }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_exact_power_law() {
        let m1 = 1000.0;
        let xs: Vec<f64> = (1..=16).map(|p| p as f64).collect();
        let ys: Vec<f64> =
            xs.iter().map(|x| 500.0 + 0.2 * m1 * x.powf(1.1)).collect();
        let fit = fit_power_law(&xs, &ys, m1);
        assert!((fit.n - 1.1).abs() < 0.01, "n = {}", fit.n);
        assert!((fit.b - 0.2).abs() < 0.01, "b = {}", fit.b);
        assert!((fit.a - 500.0).abs() < 10.0, "a = {}", fit.a);
    }

    #[test]
    fn recovers_flat_scaling() {
        // Taskflow-like: memory independent of P (n ≈ 0).
        let m1 = 1e6;
        let xs: Vec<f64> = (1..=8).map(|p| p as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|_| 5e7).collect();
        let fit = fit_power_law(&xs, &ys, m1);
        // With b≈0 any n fits; accept either tiny n or tiny b·m1·span.
        let span = (fit.b * m1 * (8f64.powf(fit.n) - 1.0)).abs();
        assert!(fit.n.abs() < 0.05 || span < 1e5, "n={} span={span}", fit.n);
    }

    #[test]
    fn tolerates_noise() {
        let m1 = 2048.0;
        let xs: Vec<f64> = (1..=12).map(|p| p as f64).collect();
        let mut rng = crate::sync::XorShift64::new(99);
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| {
                let clean = 100.0 + 3.0 * m1 * x.powf(0.9);
                clean * (1.0 + 0.02 * (rng.next_f64() - 0.5))
            })
            .collect();
        let fit = fit_power_law(&xs, &ys, m1);
        assert!((fit.n - 0.9).abs() < 0.1, "n = {} ± {}", fit.n, fit.n_err);
        assert!(fit.n_err.is_finite());
    }

    #[test]
    #[should_panic(expected = "at least 3")]
    fn too_few_points_panics() {
        fit_power_law(&[1.0, 2.0], &[1.0, 2.0], 1.0);
    }
}
