//! Small statistics helpers used by the benchmark harness (the paper
//! reports the median and standard deviation of 5 repetitions).

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Median (averages the middle pair for even lengths; 0 for empty).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Sample standard deviation (0 for fewer than two points).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 3.0, 2.0]), 2.5);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        // Sample stddev of this classic example is ~2.138.
        assert!((stddev(&xs) - 2.138).abs() < 0.01);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }
}
