//! Statistics and the Eq. (17) power-law fit for Table II.

pub mod fit;
pub mod stats;

pub use fit::{fit_power_law, PowerLawFit};
pub use stats::{mean, median, stddev};
